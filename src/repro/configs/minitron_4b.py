"""minitron-4b [dense] — arXiv:2407.14679 (hf-verified); pruned nemotron.

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.  Nemotron family
uses squared-ReLU MLPs (no gating).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    source="arXiv:2407.14679; hf",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    hidden_act="relu2",
    tie_embeddings=True,
    optimizer_moments="fp32",
)
