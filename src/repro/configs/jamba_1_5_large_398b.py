"""jamba-1.5-large-398b [hybrid] — arXiv:2403.19887 (hf-verified).

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2;
Mamba:attention 7:1 interleave (1 attn layer per 8, offset 4), MoE every
other layer.  398B total params; factored/bf16 optimizer state (DESIGN §5).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887; hf",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    hidden_act="silu",
    n_experts=16,
    experts_per_token=2,
    moe_period=2,
    moe_offset=1,
    attn_period=8,
    attn_offset=4,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    pos_embedding="none",   # jamba uses no positional encoding
    tie_embeddings=False,
    capacity_factor=1.0,
    optimizer_moments="factored",
    kv_cache_dtype="int8",
)
