"""grok-1-314b [moe] — hf:xai-org/grok-1 (unverified).

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8e top-2.
Memory policy: factored second moment + bf16 first moment (314B params on
256 chips leaves no room for 12 B/param optimizer state; DESIGN.md §5).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    source="hf:xai-org/grok-1; unverified",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    hidden_act="gelu",
    n_experts=8,
    experts_per_token=2,
    moe_period=1,
    logit_softcap=30.0,
    tie_embeddings=True,
    capacity_factor=1.0,
    optimizer_moments="factored",
    kv_cache_dtype="int8",
)
