"""gemma3-27b [dense] — hf:google/gemma-3-1b-pt family (unverified).

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144; 5 local
(sliding-window 1024) : 1 global interleave; 128k context.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    source="hf:google/gemma-3-1b-pt; unverified",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    hidden_act="gelu",
    scale_embeddings=True,
    sliding_window=1024,
    local_per_global=5,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    optimizer_moments="fp32",
)
