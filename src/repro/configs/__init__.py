from .base import ModelConfig
from .registry import ARCHS, get_config, smoke_config
from .shapes import SHAPES, input_specs, shape_cells

__all__ = ["ModelConfig", "ARCHS", "get_config", "smoke_config", "SHAPES",
           "input_specs", "shape_cells"]
