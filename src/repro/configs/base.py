"""Model configuration schema for all assigned architectures."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str = "unnamed"
    family: str = "dense"        # dense | ssm | moe | hybrid | audio | vlm
    source: str = ""             # provenance note from the assignment block

    # trunk
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 1024

    # activations / norms / embeddings
    hidden_act: str = "silu"     # silu (SwiGLU) | gelu (GeGLU) | relu2
    rope_theta: float = 10_000.0
    tie_embeddings: bool = True
    scale_embeddings: bool = False     # gemma: embed * sqrt(d_model)
    logit_softcap: Optional[float] = None
    pos_embedding: str = "rope"        # rope | learned | none

    # attention pattern
    sliding_window: Optional[int] = None
    # pattern of one repeating group, e.g. 5 local : 1 global (gemma3)
    local_per_global: int = 0          # 0 = all-global
    # hybrid interleave (jamba): one attn layer per `attn_period` layers
    attn_period: int = 0               # 0 = all layers are attention
    attn_offset: int = 0

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1                # MoE FFN every k-th layer
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0               # 0 -> ceil(d_model / 16)

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_positions: int = 0         # whisper: 1500 frames
    decoder_positions: int = 0         # whisper: learned decoder positions

    # modality frontend (STUB: input_specs supplies precomputed embeddings)
    frontend: Optional[str] = None     # audio | vision
    n_patches: int = 0                 # vlm: patch embeddings per image

    # numerics / execution
    n_microbatches: int = 1   # grad-accumulation microbatches per step
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    remat: bool = True
    scan_layers: bool = True
    # optimizer memory policy (see repro.train.optimizer)
    optimizer_moments: str = "fp32"    # fp32 | bf16 | factored
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8

    # --------------------------------------------------------------- derived
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def group_len(self) -> int:
        """Repeating layer-pattern length (for scan-over-groups)."""
        import math
        g = 1
        if self.local_per_global:
            g = self.local_per_global + 1
        if self.attn_period:
            g = max(g, self.attn_period)
        if self.n_experts and self.moe_period > 1:
            g = g * self.moe_period // math.gcd(g, self.moe_period)
        return g

    def layer_kind(self, idx: int) -> Tuple[str, str]:
        """(mixer, ffn) kind of layer ``idx``.

        mixer ∈ {attn, attn_local, attn_global, mamba}
        ffn   ∈ {dense, moe, none}
        """
        if self.family == "ssm":
            return "mamba", "none"
        if self.attn_period:
            mixer = "attn" if idx % self.attn_period == self.attn_offset else "mamba"
        elif self.local_per_global:
            mixer = (
                "attn_global"
                if idx % (self.local_per_global + 1) == self.local_per_global
                else "attn_local"
            )
        else:
            mixer = "attn"
        if self.n_experts and idx % self.moe_period == self.moe_offset:
            ffn = "moe"
        else:
            ffn = "dense"
        return mixer, ffn

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        if self.pos_embedding == "learned":
            total += (self.decoder_positions or 4096) * d
        for i in range(self.n_layers):
            mixer, ffn = self.layer_kind(i)
            if mixer.startswith("attn"):
                qkv = d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim
                total += qkv + self.n_heads * self.head_dim * d
            else:  # mamba
                di, n, r = self.d_inner, self.ssm_state, self.dt_rank
                total += d * 2 * di + di * self.ssm_conv + di * (r + 2 * n) + r * di + di * n + di + di * d
            if ffn == "dense":
                total += 3 * d * f
            elif ffn == "moe":
                total += d * self.n_experts + self.n_experts * 3 * d * f
            total += 2 * d  # norms
        if self.is_encoder_decoder:
            for _ in range(self.n_encoder_layers):
                qkv = 4 * d * self.n_heads * self.head_dim
                total += qkv + 3 * d * f + 2 * d
            # cross-attention in decoder layers
            total += self.n_layers * 4 * d * self.n_heads * self.head_dim
            total += (self.encoder_positions + (self.decoder_positions or 448)) * d
        return total

    def n_active_params(self) -> int:
        """Active per-token parameters (MoE: only routed experts count)."""
        if not self.n_experts:
            return self.n_params()
        d, f = self.d_model, self.d_ff
        total = self.n_params()
        for i in range(self.n_layers):
            _, ffn = self.layer_kind(i)
            if ffn == "moe":
                total -= (self.n_experts - self.experts_per_token) * 3 * d * f
        return total

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
