"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
from typing import Dict

from .base import ModelConfig
from . import (gemma_7b, minitron_4b, gemma3_27b, mistral_large_123b,
               falcon_mamba_7b, granite_moe_1b_a400m, grok_1_314b,
               jamba_1_5_large_398b, whisper_tiny, pixtral_12b)

ARCHS: Dict[str, ModelConfig] = {
    "gemma-7b": gemma_7b.CONFIG,
    "minitron-4b": minitron_4b.CONFIG,
    "gemma3-27b": gemma3_27b.CONFIG,
    "mistral-large-123b": mistral_large_123b.CONFIG,
    "falcon-mamba-7b": falcon_mamba_7b.CONFIG,
    "granite-moe-1b-a400m": granite_moe_1b_a400m.CONFIG,
    "grok-1-314b": grok_1_314b.CONFIG,
    "jamba-1.5-large-398b": jamba_1_5_large_398b.CONFIG,
    "whisper-tiny": whisper_tiny.CONFIG,
    "pixtral-12b": pixtral_12b.CONFIG,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: small widths/depths,
    few experts, tiny vocab — structure (interleaves, MoE, enc-dec,
    frontends) preserved."""
    cfg = get_config(arch)
    kw = dict(
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=503,
        dtype="float32",
        remat=False,
        n_microbatches=1,
    )
    if cfg.n_heads:
        kw.update(n_heads=4, n_kv_heads=min(cfg.n_kv_heads, 2) or 2, head_dim=16)
        # keep MHA archs MHA (gemma-7b kv == heads)
        if cfg.n_kv_heads == cfg.n_heads:
            kw["n_kv_heads"] = 4
    if cfg.n_experts:
        kw.update(n_experts=4, experts_per_token=min(2, cfg.experts_per_token))
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=8, ssm_dt_rank=8)
    # depth: keep ≥ one full repeating group (+ tail, to cover both paths)
    kw["n_layers"] = max(cfg.group_len + (1 if cfg.group_len > 1 else 1), 2)
    if cfg.is_encoder_decoder:
        kw.update(n_encoder_layers=2, encoder_positions=64, decoder_positions=64)
    if cfg.frontend == "vision":
        kw.update(n_patches=8)
    if cfg.sliding_window:
        kw.update(sliding_window=16)
    return dataclasses.replace(cfg, **kw)
