"""gemma-7b [dense] — arXiv:2403.08295 (hf-verified).

28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000, GeGLU, head_dim=256.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    source="arXiv:2403.08295; hf",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    hidden_act="gelu",
    scale_embeddings=True,
    tie_embeddings=True,
    logit_softcap=None,
    optimizer_moments="fp32",
)
