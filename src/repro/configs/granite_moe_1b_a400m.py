"""granite-moe-1b-a400m [moe] — hf:ibm-granite/granite-3.0-1b-a400m-base
(hf-verified).  24L d_model=1024 16H (GQA kv=8) d_ff=512/expert
vocab=49155, 32 experts top-8.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    hidden_act="silu",
    n_experts=32,
    experts_per_token=8,
    moe_period=1,
    tie_embeddings=True,
    optimizer_moments="fp32",
    # TP-MoE all-gathers the full dispatch buffer per device; 2 microbatches
    # keep the train_4k cell inside 16 GB HBM (EXPERIMENTS.md §Perf)
    n_microbatches=2,
)
