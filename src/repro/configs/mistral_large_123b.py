"""mistral-large-123b [dense] — hf:mistralai/Mistral-Large-Instruct-2407
(unverified).  88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.

int8 KV cache + factored second moment: at 123B the fp32-everything policy
does not fit 16 GB/chip on the single-pod mesh (see DESIGN.md §5).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    hidden_act="silu",
    tie_embeddings=False,
    rope_theta=1_000_000.0,
    optimizer_moments="factored",
    kv_cache_dtype="int8",
)
