"""whisper-tiny [audio] — arXiv:2212.04356 (unverified).

4L enc + 4L dec, d_model=384 6H d_ff=1536 vocab=51865; encoder-decoder
with a conv audio frontend (STUBBED: ``input_specs()`` provides the 1500
precomputed frame embeddings).  Decoder positions are learned; we extend
the table beyond the published 448 to satisfy the assigned shape cells
(noted in DESIGN.md §4).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356; unverified",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    hidden_act="gelu",
    is_encoder_decoder=True,
    n_encoder_layers=4,
    encoder_positions=1500,
    decoder_positions=448,
    pos_embedding="learned",
    frontend="audio",
    tie_embeddings=True,
    scan_layers=False,       # 4 layers: scan buys nothing
    n_microbatches=4,        # 6 heads don't shard 16-way; quarter the peak
    optimizer_moments="fp32",
)
