"""falcon-mamba-7b [ssm] — arXiv:2410.05355 (unverified); mamba-1 arch.

64L d_model=4096, attention-free, ssm_state=16, d_inner=8192 (expand 2).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    source="arXiv:2410.05355; unverified",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=65024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    pos_embedding="none",
    tie_embeddings=False,
    optimizer_moments="fp32",
)
