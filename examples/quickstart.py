"""Quickstart: the bigset CRDT public API in 60 lines.

Writes and queries go through the serve layer (the wire protocol a remote
client would speak); the cluster/vnode internals appear only where the
paper's cost claims are being shown off.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.cluster.clusters import BigsetCluster, RiakSetCluster
from repro.cluster.antientropy import sync
from repro.core.bigset import BigsetVnode
from repro.query.plan import Range
from repro.serve.bigset_service import BigsetClient, BigsetService

S = b"fruits"


def main():
    # --- a 3-replica bigset cluster behind the query service --------------
    big = BigsetCluster(3)
    client = BigsetClient(BigsetService(big))
    client.batch(S, [["add", f]
                     for f in (b"apple", b"banana", b"cherry", b"durian")])

    # observed-remove: read the causal context, hand it back (§4.3.2)
    present, ctx = client.membership(S, b"durian")
    assert present
    client.remove(S, b"durian", ctx=ctx)
    print("value (quorum r=2):", sorted(big.value(S, r=2)))

    # membership / range queries without reading the whole set (§4.4)
    print("is_member(banana):", client.membership(S, b"banana")[0])
    print("range from 'b', 2:",
          client.query(Range(S, start=b"b", limit=2)).members)

    # write cost is causal-metadata-sized, not set-sized (§4.3)
    vn = big.vnodes[big.actors[0]]
    before = vn.store.stats.snapshot()
    client.insert(S, b"elderberry")
    d = vn.store.stats.delta(before)
    print(f"one insert cost: read {d.bytes_read}B, wrote {d.bytes_written}B")

    # --- compaction shrinks the tombstone (§4.3.3) ------------------------
    big.compact_all()
    print("tombstone after compaction:", vn.read_tombstone(S))

    # --- equivalence with Riak Sets (§5) ----------------------------------
    riak = RiakSetCluster(3)
    for fruit in (b"apple", b"banana", b"cherry"):
        riak.add(S, fruit)
    assert riak.value(S, r=3) == big.value(S, r=3) - {b"elderberry"}
    print("semantically equivalent to Riak ORSWOT sets ✓")

    # --- divergent replicas converge via anti-entropy ---------------------
    a, b = BigsetVnode("a"), BigsetVnode("b")
    a.coordinate_insert(S, b"kiwi")
    b.coordinate_insert(S, b"lime")
    sync(a, b, S)
    assert a.value(S) == b.value(S) == {b"kiwi", b"lime"}
    print("anti-entropy convergence ✓")


if __name__ == "__main__":
    main()
