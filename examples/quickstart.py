"""Quickstart: the bigset CRDT public API in 60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.cluster.clusters import BigsetCluster, RiakSetCluster
from repro.cluster.antientropy import sync
from repro.core.bigset import BigsetVnode

S = b"fruits"


def main():
    # --- a 3-replica bigset cluster --------------------------------------
    big = BigsetCluster(3)
    for fruit in (b"apple", b"banana", b"cherry", b"durian"):
        big.add(S, fruit)
    big.remove(S, b"durian")
    print("value (quorum r=2):", sorted(big.value(S, r=2)))

    # membership / range queries without reading the whole set (§4.4)
    vn = big.vnodes[big.actors[0]]
    print("is_member(banana):", vn.is_member(S, b"banana")[0])
    print("range from 'b', 2:", vn.range_query(S, b"b", 2))

    # write cost is causal-metadata-sized, not set-sized (§4.3)
    before = vn.store.stats.snapshot()
    big.add(S, b"elderberry")
    d = vn.store.stats.delta(before)
    print(f"one insert cost: read {d.bytes_read}B, wrote {d.bytes_written}B")

    # --- compaction shrinks the tombstone (§4.3.3) ------------------------
    big.compact_all()
    print("tombstone after compaction:", vn.read_tombstone(S))

    # --- equivalence with Riak Sets (§5) ----------------------------------
    riak = RiakSetCluster(3)
    for fruit in (b"apple", b"banana", b"cherry"):
        riak.add(S, fruit)
    assert riak.value(S, r=3) == big.value(S, r=3) - {b"elderberry"}
    print("semantically equivalent to Riak ORSWOT sets ✓")

    # --- divergent replicas converge via anti-entropy ---------------------
    a, b = BigsetVnode("a"), BigsetVnode("b")
    a.coordinate_insert(S, b"kiwi")
    b.coordinate_insert(S, b"lime")
    sync(a, b, S)
    assert a.value(S) == b.value(S) == {b"kiwi", b"lime"}
    print("anti-entropy convergence ✓")


if __name__ == "__main__":
    main()
