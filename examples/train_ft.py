"""End-to-end fault-tolerant training driver.

Trains a small LM (default ~10M params; ``--preset 100m`` for the full-size
run) on the synthetic pipeline with:
  * dot-tracked gradient delta sync across simulated DP hosts,
  * BigStore decomposed delta checkpoints every few steps,
  * a mid-run host crash + quorum restore + elastic re-shard,
  * deterministic continuation (verified against the loss curve).

Run:  PYTHONPATH=src python examples/train_ft.py [--steps 60] [--preset 10m]
"""
import argparse

import numpy as np

from repro.configs import smoke_config
from repro.runtime.ft import FTConfig, FTTrainer

PRESETS = {
    # d_model, n_layers, d_ff, heads, seq, vocab  (~param count)
    "1m": (64, 2, 256, 4, 64, 503),
    "10m": (256, 4, 1024, 8, 128, 2048),
    "100m": (768, 12, 3072, 12, 256, 8192),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--preset", default="1m", choices=PRESETS)
    ap.add_argument("--global-batch", type=int, default=8)
    args = ap.parse_args()

    d, L, f, h, seq, vocab = PRESETS[args.preset]
    cfg = smoke_config("minitron-4b").replace(
        d_model=d, n_layers=L, d_ff=f, n_heads=h, n_kv_heads=h,
        head_dim=d // h, vocab_size=vocab)
    ft = FTConfig(n_hosts=4, global_batch=args.global_batch, seq_len=seq,
                  ckpt_every=10, replication=3)
    tr = FTTrainer(cfg, ft)
    n_params = sum(x.size for x in np_leaves(tr.state.params))
    print(f"model: {n_params / 1e6:.1f}M params, {ft.n_hosts} DP hosts")

    third = args.steps // 3
    losses = tr.train_steps(third)
    print(f"[phase 1] steps 1..{third}: loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    # ---- crash a host mid-run -------------------------------------------
    tr.checkpoint()
    tr.crash_host(2)
    print(f"[fault] host 2 crashed; alive assignment:",
          tr.elastic.current_assignment().hosts)
    step = tr.restore()  # quorum restore from surviving replicas
    print(f"[restore] resumed from step {step} via quorum streaming fold")

    losses = tr.train_steps(third)
    print(f"[phase 2] 3-host elastic continuation: "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    # ---- node replacement joins ------------------------------------------
    tr.join_host(2)
    print("[elastic] host 2 replacement joined:",
          tr.elastic.current_assignment().hosts)
    losses = tr.train_steps(args.steps - 2 * third,
                            slow_hosts={"node1": 2})  # transient straggler
    print(f"[phase 3] 4-host + straggler sealing: "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    hist = tr.loss_history
    print(f"\nfinal: {hist[-1]:.3f} (start {hist[0]:.3f}); "
          f"ckpt store {tr.store.total_bytes() / 1e6:.1f} MB across "
          f"{sum(h.alive for h in tr.store.hosts)} hosts")
    assert np.mean(hist[-5:]) < np.mean(hist[:5]), "loss did not improve"
    print("loss improved across crash/restore/elastic events ✓")


def np_leaves(tree):
    import jax
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


if __name__ == "__main__":
    main()
