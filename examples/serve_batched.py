"""Batched serving example: continuous batching with streamed tokens.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np
import jax

from repro.configs import smoke_config
from repro.models import build_model
from repro.serve.engine import ServeEngine


def main():
    cfg = smoke_config("pixtral-12b").replace(
        n_layers=2, kv_cache_dtype="bfloat16")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, max_batch=4, max_len=96, temperature=0.0)

    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, rng.integers(4, 12)),
                       max_new_tokens=8) for _ in range(7)]
    print(f"submitted {len(reqs)} requests (queue depth > batch: "
          f"continuous batching kicks in)")

    it = 0
    while eng.queue or any(s is not None for s in eng.slots):
        active = eng.step()
        it += 1
        done = sum(r.done for r in reqs)
        print(f"  iter {it:2d}: {active} active slots, {done}/{len(reqs)} done")
    for r in reqs:
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")
    assert all(r.done for r in reqs)
    print("all requests served ✓")


if __name__ == "__main__":
    main()
