"""Partition/heal demo: bigset under an adversarial network.

Two "sides" of a partitioned 4-replica cluster take writes independently
(including a remove of an element the other side concurrently re-adds),
then heal via anti-entropy — all replicas converge, add-wins.  Client
traffic (writes, membership with causal context, the final scan) goes
through the serve layer's wire protocol.

Run:  PYTHONPATH=src python examples/bigset_cluster.py
"""
from repro.cluster.antientropy import sync
from repro.cluster.clusters import BigsetCluster
from repro.cluster.sim import Network
from repro.query.plan import Scan
from repro.serve.bigset_service import BigsetClient, BigsetService

S = b"cart"


def main():
    net = Network(seed=7, drop_prob=0.0)
    big = BigsetCluster(4, net=net, sync=False)  # manual delivery
    client = BigsetClient(BigsetService(big))

    client.insert(S, b"book")
    big.settle()
    print("before partition:", sorted(big.value(S, r=4)))

    # ---- partition: {0,1} | {2,3}; deltas between sides are dropped ------
    big.net.drop_prob = 1.0  # total partition (simplified: drop everything)
    # side A reads book's causal context (r=1: only its own side answers),
    # then removes exactly what it observed
    _, ctx = client.membership(S, b"book", r=1)
    client.remove(S, b"book", ctx=ctx)          # side A removes the book
    big.add(S, b"book", 2)                      # side B re-adds concurrently
    big.add(S, b"pen", 3)
    big.net.queue.clear()
    big.net.drop_prob = 0.0

    print("side A view:", sorted(big.vnodes[big.actors[0]].value(S)))
    print("side B view:", sorted(big.vnodes[big.actors[2]].value(S)))

    # ---- heal: ring anti-entropy ------------------------------------------
    vns = [big.vnodes[a] for a in big.actors]
    for _ in range(2):
        for i in range(4):
            sync(vns[i], vns[(i + 1) % 4], S)

    views = [sorted(vn.value(S)) for vn in vns]
    print("after heal:", views[0])
    assert all(v == views[0] for v in views), "replicas diverged!"
    assert b"book" in set(views[0]), "add-wins violated"
    print("converged; concurrent re-add beat the remove (add-wins) ✓")

    # the healed set, served: a paginated scan over the full quorum
    members = [el for page in client.pages(Scan(S, page_size=1), r=4)
               for el in page.members]
    assert members == views[0], (members, views[0])
    print("served scan agrees with every replica ✓")

    # storage hygiene after churn
    for vn in vns:
        vn.compact()
    print("tombstones after compaction:",
          [str(vn.read_tombstone(S)) for vn in vns])


if __name__ == "__main__":
    main()
