"""Serve layer: wire-protocol throughput/latency + backpressure saturation.

Measures the full client→service→cluster→LSM path of
:mod:`repro.serve.bigset_service`: batch inserts, point membership probes,
and cursor-paginated scans (all msgpack-round-tripped, exactly what a
remote client pays), plus a *saturation* row where the byte budget is
deliberately tiny so admission control engages — the derived column
records how many pages were rejected and that every rejected page was
resumed from its preserved cursor (``resumed=all``).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.cluster.clusters import BigsetCluster
from repro.kernels.dot_seen.ops import DISPATCHES
from repro.obs.trace import Tracer
from repro.query.plan import Membership, Scan
from repro.serve.bigset_service import (Backpressure, BigsetClient,
                                        BigsetService, ServiceConfig)

SET = b"servebench"
PAGE = 250


def build(card: int):
    cluster = BigsetCluster(3)
    service = BigsetService(cluster)
    client = BigsetClient(service)
    t0 = time.perf_counter()
    for base in range(0, card, 1000):
        client.batch(SET, [["add", b"%08d" % i]
                           for i in range(base, min(base + 1000, card))])
    insert_us = (time.perf_counter() - t0) / card * 1e6
    return cluster, service, client, insert_us


def bench_point(client: BigsetClient, card: int, n_ops: int, rng) -> float:
    t0 = time.perf_counter()
    for _ in range(n_ops):
        el = b"%08d" % int(rng.integers(card))
        client.query(Membership(SET, el))
    return (time.perf_counter() - t0) / n_ops * 1e6


def bench_scan(client: BigsetClient, card: int):
    pages = 0
    page_bytes = 0
    seen = 0
    t0 = time.perf_counter()
    for page in client.pages(Scan(SET, page_size=PAGE)):
        pages += 1
        seen += len(page.entries)
        page_bytes += page.stats["bytes_read"]
    dt = time.perf_counter() - t0
    assert seen == card, (seen, card)
    return dt / pages * 1e6, page_bytes // pages


def bench_saturation(cluster: BigsetCluster, card: int,
                     tracer: Tracer | None = None):
    """Scan through a budget sized to a couple of pages; a fake clock makes
    the backoff free, so the row isolates admission-control overhead.  Also
    reports amortized dot_seen launches per page (the micro-batcher
    baseline) from the process-wide :data:`DISPATCHES` ledger."""
    clk = [0.0]
    service = BigsetService(
        cluster,
        ServiceConfig(byte_budget=2 * PAGE * 64, budget_window=1.0,
                      lease_ttl=1e9),
        clock=lambda: clk[0],
        tracer=tracer)
    client = BigsetClient(service)

    def advance(seconds: float) -> None:
        clk[0] += seconds + 1e-3

    saved_tracer = cluster.tracer
    if tracer is not None:  # trace the cluster path too, not just serve
        cluster.tracer = tracer
    seen = pages = 0
    before = DISPATCHES.snapshot()
    t0 = time.perf_counter()
    try:
        for page in client.pages(Scan(SET, page_size=PAGE), sleep=advance):
            pages += 1
            seen += len(page.entries)
    finally:
        cluster.tracer = saved_tracer
    dt = time.perf_counter() - t0
    launches = DISPATCHES.delta(before).launches
    assert seen == card, (seen, card)  # rejection never loses a cursor
    return dt / pages * 1e6, service.rejections, launches / pages


def main(cards=(1000, 5000), n_ops=100, quick=False) -> List[str]:
    if quick:
        cards, n_ops = (500,), 30
    rows = []
    for card in cards:
        rng = np.random.default_rng(11)
        cluster, service, client, insert_us = build(card)
        rows.append(f"serve/insert/{card},{insert_us:.1f},card={card}")
        member_us = bench_point(client, card, n_ops, rng)
        rows.append(f"serve/membership/{card},{member_us:.1f},card={card}")
        page_us, bytes_per_page = bench_scan(client, card)
        rows.append(
            f"serve/scan_page/{card},{page_us:.1f},"
            f"bytes_per_page={bytes_per_page}")
        sat_us, rejected, launches_pp = bench_saturation(cluster, card)
        rows.append(
            f"serve/saturation/{card},{sat_us:.1f},"
            f"rejected={rejected};resumed=all;"
            f"launches_per_query={launches_pp:.2f}")
        # Same workload with tracing on: the derived overhead_pct is the
        # acceptance check that instrumentation costs < 5% when enabled
        # (and exactly nothing when disabled — that's this very row above,
        # which runs through the NULL_TRACER fast path).
        traced_us, _, _ = bench_saturation(cluster, card, tracer=Tracer())
        overhead = (traced_us - sat_us) / sat_us * 100.0
        rows.append(
            f"serve/saturation_traced/{card},{traced_us:.1f},"
            f"overhead_pct={overhead:.1f}")
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
