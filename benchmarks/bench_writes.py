"""Paper Table 1 / Figures 1-3: write performance vs cardinality.

Inserts N unique elements (4-byte, as in the paper) into one set on a
3-replica cluster for each contender — Riak Sets (full-state), Deltas
(delta replication, full-state disk), Bigsets — measuring throughput,
mean/95th latency, and the byte cost the paper's §2.1 analysis predicts:
O(n²) lifetime bytes for blob-backed sets vs O(n·Δ) for bigset.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.cluster.clusters import BigsetCluster, DeltaCluster, RiakSetCluster


def run_writes(cluster, n: int) -> Dict[str, float]:
    S = b"s"
    lat = []
    t0 = time.perf_counter()
    for i in range(n):
        elem = i.to_bytes(4, "big")           # 4-byte elements, as in paper
        t1 = time.perf_counter()
        cluster.add(S, elem, coordinator=i % 3)
        lat.append(time.perf_counter() - t1)
    wall = time.perf_counter() - t0
    io = cluster.io_stats()
    lat_us = np.array(lat) * 1e6
    return {
        "ops": n,
        "throughput_ops_s": n / wall,
        "mean_us": float(lat_us.mean()),
        "p95_us": float(np.percentile(lat_us, 95)),
        "bytes_read": io.bytes_read,
        "bytes_written": io.bytes_written,
        "net_bytes": cluster.net.bytes_sent,
        "bytes_per_op": (io.bytes_read + io.bytes_written) / n,
    }


def run_durable(n: int, group_depth: int) -> Dict[str, float]:
    """Durable bigset writes: WAL + group commit at the given depth."""
    cluster = BigsetCluster(3, durable=True, group_depth=group_depth)
    r = run_writes(cluster, n)
    cluster.sync_all()                            # ack the tail
    io = cluster.io_stats()
    r["bytes_wal"] = io.bytes_wal
    r["num_fsyncs"] = io.num_fsyncs
    # each coordinated add lands one batch on every replica
    r["batches"] = n * len(cluster.actors)
    return r


def main(cards=(500, 2000, 5000), quick=False) -> List[str]:
    if quick:
        cards = (200, 500, 1000)
    rows = []
    for n in cards:
        for name, cls in (("riak", RiakSetCluster), ("delta", DeltaCluster),
                          ("bigset", BigsetCluster)):
            r = run_writes(cls(3), n)
            rows.append(
                f"writes/{name}/{n},{1e6 / r['throughput_ops_s']:.1f},"
                f"tp={r['throughput_ops_s']:.0f}ops/s;mean={r['mean_us']:.0f}us;"
                f"p95={r['p95_us']:.0f}us;bytes_per_op={r['bytes_per_op']:.0f};"
                f"net={r['net_bytes']}")
        for depth in (1, 8):
            r = run_durable(n, depth)
            if depth >= 8 and not r["num_fsyncs"] < r["batches"]:
                raise RuntimeError(
                    f"group commit did not amortize: {r['num_fsyncs']} fsyncs "
                    f"for {r['batches']} batches at depth {depth}")
            rows.append(
                f"writes/bigset-durable-d{depth}/{n},"
                f"{1e6 / r['throughput_ops_s']:.1f},"
                f"tp={r['throughput_ops_s']:.0f}ops/s;mean={r['mean_us']:.0f}us;"
                f"fsyncs={r['num_fsyncs']};batches={r['batches']};"
                f"wal_bytes={r['bytes_wal']}")
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
