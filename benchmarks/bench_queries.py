"""Paper §4.4: query latency vs cardinality for all three contenders.

The paper's read trade-off ("decomposition hurts full reads") is "mitigated
by enabling queries on sets": membership is a seek, ranges stream only their
result, and cross-set joins zipper two ordered key ranges.  A blob store
must deserialize the *entire* set to answer any of these.  This benchmark
makes that claim a number: membership / range / intersect-join latency at
growing cardinality for riak (full-state blob), delta (blob disk path), and
bigset (decomposed + query engine).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.cluster.clusters import BigsetCluster, DeltaCluster, RiakSetCluster
from repro.index import by_element_suffix
from repro.query import IndexLookup, Join, Membership, Range, Scan

LEFT = b"qleft"
RIGHT = b"qright"
RANGE_LIMIT = 25
# secondary index: last element byte (a 256-way hash-bucket style index);
# one bucket is a ~1/256-selective predicate over LEFT
SUFFIX_INDEX = by_element_suffix(1)


def build(cluster, card: int):
    """Two overlapping sets: RIGHT holds every other element of LEFT + tail."""
    for i in range(card):
        cluster.add(LEFT, i.to_bytes(4, "big"), coordinator=i % cluster.n)
        if i % 2 == 0:
            cluster.add(RIGHT, i.to_bytes(4, "big"), coordinator=i % cluster.n)
    for i in range(card, card + card // 4):
        cluster.add(RIGHT, i.to_bytes(4, "big"), coordinator=i % cluster.n)
    return cluster


def _time(fn, n_ops: int) -> float:
    t0 = time.perf_counter()
    for _ in range(n_ops):
        fn()
    return (time.perf_counter() - t0) / n_ops * 1e6  # us/op


def run_blob(cluster, card: int, n_ops: int, rng) -> dict:
    """Blob contenders answer every query by materialising the whole set."""
    def member():
        e = int(rng.integers(card)).to_bytes(4, "big")
        return e in cluster.read(LEFT).value()

    def range_q():
        lo = int(rng.integers(card)).to_bytes(4, "big")
        vals = sorted(v for v in cluster.read(LEFT).value() if v >= lo)
        return vals[:RANGE_LIMIT]

    def join_q():
        return cluster.read(LEFT).value() & cluster.read(RIGHT).value()

    return {
        "member_us": _time(member, n_ops),
        "range_us": _time(range_q, n_ops),
        "join_us": _time(join_q, max(1, n_ops // 4)),
    }


def run_bigset(cluster: BigsetCluster, card: int, n_ops: int, rng,
               r: int = 1) -> dict:
    def member():
        e = int(rng.integers(card)).to_bytes(4, "big")
        return cluster.query(Membership(LEFT, e), r=r).present

    def range_q():
        lo = int(rng.integers(card)).to_bytes(4, "big")
        return cluster.query(Range(LEFT, start=lo, limit=RANGE_LIMIT), r=r)

    def join_q():
        return cluster.query(Join("intersect", LEFT, RIGHT), r=r)

    return {
        "member_us": _time(member, n_ops),
        "range_us": _time(range_q, n_ops),
        "join_us": _time(join_q, max(1, n_ops // 4)),
    }


def run_index(cluster: BigsetCluster, card: int, n_ops: int, rng,
              r: int = 1) -> dict:
    """Index-scan vs full-scan-and-filter for the same selective predicate.

    ``index_scan`` seeks the posting range of one suffix bucket;
    ``full_filter`` is what a set without indexes must do — page the whole
    element range and filter in the client.  Both answer "elements whose
    last byte is B", so the latency *and* bytes-read gap is pure index win.
    """
    def bucket() -> bytes:
        # sample populated buckets only: LEFT holds 0..card-1 big-endian,
        # so last bytes cover 0..min(card, 256)-1 — an empty bucket would
        # measure a metadata-only seek, not a selective match
        return bytes([int(rng.integers(min(card, 256)))])

    def index_scan():
        return cluster.query(
            IndexLookup(LEFT, SUFFIX_INDEX.name, bucket()), r=r)

    def scan_and_filter(b: bytes):
        """Page the whole set, filter client-side; returns (hits, bytes)."""
        out, total, cur = [], 0, None
        while True:
            res = cluster.query(Scan(LEFT, page_size=2048, cursor=cur), r=r)
            out.extend(e for e, _ in res.entries if e[-1:] == b)
            total += res.stats.bytes_read
            cur = res.cursor
            if cur is None:
                return out, total

    n_full = max(1, n_ops // 10)
    out = {
        "index_scan_us": _time(index_scan, n_ops),
        "full_filter_us": _time(lambda: scan_and_filter(bucket()), n_full),
    }
    # per-query IoStats: the O(matches + causal metadata) claim as bytes.
    # bucket 0 is always populated (elements 0, 256, 512, ...)
    out["index_scan_bytes"] = cluster.query(
        IndexLookup(LEFT, SUFFIX_INDEX.name, b"\x00"), r=r).stats.bytes_read
    out["full_filter_bytes"] = scan_and_filter(b"\x00")[1]
    return out


def main(cards=(100, 1000, 4000), n_ops=60, quick=False) -> List[str]:
    if quick:
        cards, n_ops = (50, 200), 20
    rows = []
    for card in cards:
        rng = np.random.default_rng(7)
        contenders = [
            ("riak", run_blob, build(RiakSetCluster(3), card)),
            ("delta", run_blob, build(DeltaCluster(3), card)),
            ("bigset", None, None),  # built below with compaction
        ]
        big = BigsetCluster(3)
        big.register_index(LEFT, SUFFIX_INDEX)  # indexed on the write path
        build(big, card)
        big.compact_all()
        for name, runner, cluster in contenders:
            if name == "bigset":
                q = run_bigset(big, card, n_ops, rng)
            else:
                q = runner(cluster, card, n_ops, rng)
            for shape in ("member", "range", "join"):
                rows.append(
                    f"queries/{name}/{shape}/{card},{q[shape + '_us']:.1f},"
                    f"card={card}")
        idx = run_index(big, card, n_ops, rng)
        for shape in ("index_scan", "full_filter"):
            rows.append(
                f"queries/bigset/{shape}/{card},{idx[shape + '_us']:.1f},"
                f"card={card}")
            rows.append(
                f"queries/bigset/{shape}_bytes/{card},"
                f"{idx[shape + '_bytes']},card={card}")
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
