"""Paper §4.4: query latency vs cardinality for all three contenders.

The paper's read trade-off ("decomposition hurts full reads") is "mitigated
by enabling queries on sets": membership is a seek, ranges stream only their
result, and cross-set joins zipper two ordered key ranges.  A blob store
must deserialize the *entire* set to answer any of these.  This benchmark
makes that claim a number: membership / range / intersect-join latency at
growing cardinality for riak (full-state blob), delta (blob disk path), and
bigset (decomposed + query engine).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.cluster.clusters import BigsetCluster, DeltaCluster, RiakSetCluster
from repro.query import Join, Membership, Range

LEFT = b"qleft"
RIGHT = b"qright"
RANGE_LIMIT = 25


def build(cluster, card: int):
    """Two overlapping sets: RIGHT holds every other element of LEFT + tail."""
    for i in range(card):
        cluster.add(LEFT, i.to_bytes(4, "big"), coordinator=i % cluster.n)
        if i % 2 == 0:
            cluster.add(RIGHT, i.to_bytes(4, "big"), coordinator=i % cluster.n)
    for i in range(card, card + card // 4):
        cluster.add(RIGHT, i.to_bytes(4, "big"), coordinator=i % cluster.n)
    return cluster


def _time(fn, n_ops: int) -> float:
    t0 = time.perf_counter()
    for _ in range(n_ops):
        fn()
    return (time.perf_counter() - t0) / n_ops * 1e6  # us/op


def run_blob(cluster, card: int, n_ops: int, rng) -> dict:
    """Blob contenders answer every query by materialising the whole set."""
    def member():
        e = int(rng.integers(card)).to_bytes(4, "big")
        return e in cluster.read(LEFT).value()

    def range_q():
        lo = int(rng.integers(card)).to_bytes(4, "big")
        vals = sorted(v for v in cluster.read(LEFT).value() if v >= lo)
        return vals[:RANGE_LIMIT]

    def join_q():
        return cluster.read(LEFT).value() & cluster.read(RIGHT).value()

    return {
        "member_us": _time(member, n_ops),
        "range_us": _time(range_q, n_ops),
        "join_us": _time(join_q, max(1, n_ops // 4)),
    }


def run_bigset(cluster: BigsetCluster, card: int, n_ops: int, rng,
               r: int = 1) -> dict:
    def member():
        e = int(rng.integers(card)).to_bytes(4, "big")
        return cluster.query(Membership(LEFT, e), r=r).present

    def range_q():
        lo = int(rng.integers(card)).to_bytes(4, "big")
        return cluster.query(Range(LEFT, start=lo, limit=RANGE_LIMIT), r=r)

    def join_q():
        return cluster.query(Join("intersect", LEFT, RIGHT), r=r)

    return {
        "member_us": _time(member, n_ops),
        "range_us": _time(range_q, n_ops),
        "join_us": _time(join_q, max(1, n_ops // 4)),
    }


def main(cards=(100, 1000, 4000), n_ops=60, quick=False) -> List[str]:
    if quick:
        cards, n_ops = (50, 200), 20
    rows = []
    for card in cards:
        rng = np.random.default_rng(7)
        contenders = [
            ("riak", run_blob, build(RiakSetCluster(3), card)),
            ("delta", run_blob, build(DeltaCluster(3), card)),
            ("bigset", None, None),  # built below with compaction
        ]
        big = build(BigsetCluster(3), card)
        big.compact_all()
        for name, runner, cluster in contenders:
            if name == "bigset":
                q = run_bigset(big, card, n_ops, rng)
            else:
                q = runner(cluster, card, n_ops, rng)
            for shape in ("member", "range", "join"):
                rows.append(
                    f"queries/{name}/{shape}/{card},{q[shape + '_us']:.1f},"
                    f"card={card}")
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
