"""Paper Figure 6 / Table 2 Mix rows: 60/40 write-to-read mixed workload."""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.cluster.clusters import BigsetCluster, RiakSetCluster


def run_mixed(cluster, n_keys: int, n_ops: int, seed: int = 0,
              preload: int = 1000):
    rng = np.random.default_rng(seed)
    w_lat, r_lat = [], []
    counters = [0] * n_keys
    # paper's mixed runs hit ~1k-cardinality sets; preload to match
    for k in range(n_keys):
        S = b"set%03d" % k
        for i in range(preload):
            cluster.add(S, i.to_bytes(4, "big"), coordinator=i % 3)
        counters[k] = preload
    t0 = time.perf_counter()
    for i in range(n_ops):
        k = int(rng.integers(n_keys))
        S = b"set%03d" % k
        if rng.random() < 0.6:  # 60% writes
            t1 = time.perf_counter()
            cluster.add(S, counters[k].to_bytes(4, "big"), coordinator=i % 3)
            w_lat.append(time.perf_counter() - t1)
            counters[k] += 1
        else:
            t1 = time.perf_counter()
            cluster.value(S, r=1)
            r_lat.append(time.perf_counter() - t1)
    wall = time.perf_counter() - t0
    io = cluster.io_stats()
    return {
        "tp": n_ops / wall,
        "w_mean_us": float(np.mean(w_lat) * 1e6) if w_lat else 0.0,
        "w_p99_us": float(np.percentile(w_lat, 99) * 1e6) if w_lat else 0.0,
        "r_mean_us": float(np.mean(r_lat) * 1e6) if r_lat else 0.0,
        "r_p99_us": float(np.percentile(r_lat, 99) * 1e6) if r_lat else 0.0,
        "io_bytes": io.bytes_read + io.bytes_written,
    }


def main(n_keys=10, n_ops=1500, quick=False) -> List[str]:
    preload = 1000
    if quick:
        n_keys, n_ops, preload = 6, 300, 150
    rows = []
    for name, cls in (("riak", RiakSetCluster), ("bigset", BigsetCluster)):
        r = run_mixed(cls(3), n_keys, n_ops, preload=preload)
        rows.append(
            f"mixed60w40r/{name},{1e6 / r['tp']:.1f},"
            f"tp={r['tp']:.0f};w_mean={r['w_mean_us']:.0f}us;"
            f"w_p99={r['w_p99_us']:.0f}us;r_mean={r['r_mean_us']:.0f}us;"
            f"r_p99={r['r_p99_us']:.0f}us;io={r['io_bytes']}")
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
