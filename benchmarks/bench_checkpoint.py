"""Framework-plane benchmark: monolithic vs BigStore delta checkpointing.

The paper's O(n) blob-write vs O(Δ) decomposed-write comparison, applied to
train-state durability.  The "monolithic" baseline serializes the whole
shard-dict into one blob per save (what a naive Orbax-style store does
per-host); BigStore writes only changed shards + causal metadata.
Scenario models an MoE fine-tune: per save, only ``hot_frac`` of shards
change (cold experts / frozen embeddings unchanged).
"""
from __future__ import annotations

import time
from typing import List

import msgpack
import numpy as np

from repro.checkpoint.bigstore import BigStore
from repro.storage.lsm import LsmStore


def make_shards(rng, n_shards=48, shard_elems=4096):
    return {f"layer{i:02d}/w": rng.standard_normal(
        (shard_elems,)).astype(np.float32) for i in range(n_shards)}


def run_monolithic(steps: int, hot_frac: float, seed=0, replicas=3):
    rng = np.random.default_rng(seed)
    shards = make_shards(rng)
    stores = [LsmStore() for _ in range(replicas)]  # blob replicated R-way,
    t0 = time.perf_counter()                        # like BigStore's R=3
    for s in range(steps):
        for name in list(shards)[: int(len(shards) * hot_frac)]:
            shards[name] = shards[name] + 1.0
        blob = msgpack.packb({k: v.tobytes() for k, v in shards.items()})
        for store in stores:
            store.put(b"ckpt", blob)  # whole-state rewrite every save
    wall = time.perf_counter() - t0
    return {"bytes_written": sum(st.stats.bytes_written for st in stores),
            "wall_s": wall}


def run_bigstore(steps: int, hot_frac: float, seed=0):
    rng = np.random.default_rng(seed)
    shards = make_shards(rng)
    store = BigStore(4, replication=3)
    t0 = time.perf_counter()
    for s in range(steps):
        for name in list(shards)[: int(len(shards) * hot_frac)]:
            shards[name] = shards[name] + 1.0
        store.save(b"run", shards, step=s + 1)
    store.compact_all()
    wall = time.perf_counter() - t0
    io = store.io_stats()
    # restore after killing a host (fault-tolerance cost check)
    store.kill(0)
    t1 = time.perf_counter()
    got = store.restore(b"run", expect=shards.keys())
    restore_s = time.perf_counter() - t1
    assert len(got) == len(shards)
    return {"bytes_written": io.bytes_written, "wall_s": wall,
            "restore_s": restore_s}


def main(steps=12, quick=False) -> List[str]:
    if quick:
        steps = 5
    rows = []
    for hot in (1.0, 0.25, 0.05):
        mono = run_monolithic(steps, hot)
        big = run_bigstore(steps, hot)
        ratio = mono["bytes_written"] / max(big["bytes_written"], 1)
        rows.append(
            f"ckpt/monolithic/hot{hot},{mono['wall_s'] * 1e6 / steps:.0f},"
            f"bytes={mono['bytes_written']}")
        rows.append(
            f"ckpt/bigstore/hot{hot},{big['wall_s'] * 1e6 / steps:.0f},"
            f"bytes={big['bytes_written']};mono_ratio={ratio:.2f};"
            f"restore_s={big['restore_s']:.3f}")
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
