"""Clock representation benchmark: interval runs vs the legacy per-dot cloud.

The paper's bound is that clock cost tracks *causal metadata*.  The legacy
``frozenset``-cloud representation broke that on churn: one removal below
the base fragments the survivors digest permanently, so wire bytes and
digest-compare cost grow with *removed dots*.  Interval runs restore the
bound — cost grows with live *runs*.

Rows, per churn fraction, on an ``n``-element single-actor set with
span-granular random removals (spans of ~64 contiguous dots — element
churn is bursty, not uniform):

* ``wire/...`` — serialized survivors-digest bytes: the run-length codec
  (``Clock.to_obj``) vs the legacy per-dot ``{"b", "c"}`` msgpack codec
  of the *same* dot set.
* ``diff/...`` — digest subtraction between two replicas diverged by
  ``k`` spans: ``diff_runs`` (O(runs)) vs the legacy set-of-dots
  difference (O(events)).
* ``sync/converged_churned`` — a churned, converged vnode pair still
  syncs with **zero element folds** (digest-only round).

**Gate** (acceptance): at n=100k / 50% churn the interval representation
must beat legacy by ≥ 10× on both wire bytes and diff cost, and the
converged round must fold no element ranges.  The gate raises, failing
the quick-bench job, rather than silently reporting a regression.
"""
from __future__ import annotations

import time
from typing import Dict, FrozenSet, List, Set, Tuple

import msgpack

from repro.cluster.antientropy import sync_pull
from repro.core.bigset import BigsetVnode
from repro.core.clock import Clock
from repro.storage.lsm import LsmStore

S = b"churnset"
SPAN = 64          # contiguous dots per removal burst
GATE = 10.0        # required interval-vs-legacy advantage at 50% churn


# ----------------------------------------------------------------- legacy model
class LegacyCloudClock:
    """The pre-refactor representation: BaseVV + per-actor frozenset cloud.

    Enough of the old surface to price its wire bytes and diff cost
    honestly: the base compresses only the contiguous prefix, every dot
    above the first hole is a cloud member.
    """

    def __init__(self, dots_by_actor: Dict[str, Set[int]]):
        self.base: Dict[str, int] = {}
        self.cloud: Dict[str, FrozenSet[int]] = {}
        for a, cs in dots_by_actor.items():
            b = 0
            while (b + 1) in cs:
                b += 1
            if b:
                self.base[a] = b
            rest = frozenset(c for c in cs if c > b)
            if rest:
                self.cloud[a] = rest

    def to_bytes(self) -> bytes:
        return msgpack.packb({
            "b": sorted(self.base.items()),
            "c": sorted((a, sorted(s)) for a, s in self.cloud.items()),
        })

    def dot_set(self) -> Set[Tuple[str, int]]:
        out = {(a, c) for a in self.base for c in range(1, self.base[a] + 1)}
        for a, s in self.cloud.items():
            out.update((a, c) for c in s)
        return out


# ------------------------------------------------------------------- churn model
def churned_counters(n: int, frac: float, seed: int) -> Tuple[Set[int], int]:
    """Live counters of ``[1, n]`` after removing ``frac`` in SPAN-bursts."""
    import random

    rng = random.Random(seed)
    n_spans = int(n * frac) // SPAN
    slots = rng.sample(range(n // SPAN), n_spans)
    removed: Set[int] = set()
    for s in slots:
        removed.update(range(s * SPAN + 1, (s + 1) * SPAN + 1))
    return set(range(1, n + 1)) - removed, n_spans


def _runs_of(live: Set[int]) -> List[Tuple[str, int, int]]:
    out = []
    lo = prev = None
    for c in sorted(live):
        if prev is None or c != prev + 1:
            if prev is not None:
                out.append(("x", lo, prev))
            lo = c
        prev = c
    if prev is not None:
        out.append(("x", lo, prev))
    return out


def build_clock(live: Set[int]) -> Clock:
    return Clock.zero().add_runs(_runs_of(live))


# ------------------------------------------------------------------------ bench
def main(quick: bool = False) -> List[str]:
    n = 100_000
    fracs = (0.1, 0.5) if quick else (0.1, 0.25, 0.5)
    reps = 3 if quick else 10
    rows: List[str] = []
    gates: Dict[str, float] = {}

    for frac in fracs:
        live, n_spans = churned_counters(n, frac, seed=7)
        clk = build_clock(live)
        legacy = LegacyCloudClock({"x": live})
        tag = f"churn{int(frac * 100)}"

        # ------------------------------------------------------- wire bytes
        t0 = time.perf_counter()
        for _ in range(reps):
            iv_bytes = len(msgpack.packb(clk.to_obj()))
        iv_us = (time.perf_counter() - t0) / reps * 1e6
        t0 = time.perf_counter()
        for _ in range(reps):
            lg_bytes = len(legacy.to_bytes())
        lg_us = (time.perf_counter() - t0) / reps * 1e6
        rows.append(f"clock/wire/{tag}/interval,{iv_us:.1f},"
                    f"bytes={iv_bytes};runs={clk.n_runs()}")
        rows.append(f"clock/wire/{tag}/legacy,{lg_us:.1f},"
                    f"bytes={lg_bytes};cloud_dots="
                    f"{sum(len(s) for s in legacy.cloud.values())}")

        # -------------------------------------------- diff (digest compare)
        # replica B lags by the last ~1/8 of the removal spans healed back
        live_b, _ = churned_counters(n, frac, seed=7)
        for a, lo, hi in _runs_of(set(range(1, n + 1)) - live_b)[
                : max(1, n_spans // 8)]:
            live_b.update(range(lo, hi + 1))
        clk_b = build_clock(live_b)
        legacy_b = LegacyCloudClock({"x": live_b})

        t0 = time.perf_counter()
        for _ in range(reps):
            diff_runs = clk_b.diff_runs(clk)
        iv_diff_us = (time.perf_counter() - t0) / reps * 1e6
        t0 = time.perf_counter()
        for _ in range(reps):
            diff_legacy = legacy_b.dot_set() - legacy.dot_set()
        lg_diff_us = (time.perf_counter() - t0) / reps * 1e6
        diverged = sum(hi - lo + 1 for _, lo, hi in diff_runs)
        assert diverged == len(diff_legacy)  # same answer, different cost
        rows.append(f"clock/diff/{tag}/interval,{iv_diff_us:.1f},"
                    f"diverged_runs={len(diff_runs)};diverged_dots={diverged}")
        rows.append(f"clock/diff/{tag}/legacy,{lg_diff_us:.1f},"
                    f"diverged_dots={len(diff_legacy)}")

        if frac == 0.5:
            gates["wire_bytes"] = lg_bytes / iv_bytes
            gates["diff_cost"] = lg_diff_us / max(iv_diff_us, 1e-9)

    # ------------------------------------- churned converged pair still skips
    m = 1_000 if quick else 10_000
    a = BigsetVnode("a", LsmStore(memtable_limit=1 << 20))
    b = BigsetVnode("b", LsmStore(memtable_limit=1 << 20))
    for i in range(m):
        b.replica_insert(a.coordinate_insert(S, b"%08d" % i))
    for i in range(0, m, 2):                      # 50% removals
        _, ctx = a.is_member(S, b"%08d" % i)
        b.replica_remove(a.coordinate_remove(S, ctx))
    a.store.flush()
    b.store.flush()
    sync_pull(a, b, S)                            # settle buffered digests
    sync_pull(b, a, S)
    folds0 = a.store.stats.num_seeks + b.store.stats.num_seeks
    t0 = time.perf_counter()
    r1 = sync_pull(a, b, S)
    r2 = sync_pull(b, a, S)
    us = (time.perf_counter() - t0) * 1e6
    folds = a.store.stats.num_seeks + b.store.stats.num_seeks - folds0
    rows.append(f"clock/sync/converged_churned/n{m},{us:.1f},"
                f"element_folds={folds};skipped={r1.skipped and r2.skipped};"
                f"digest_bytes={r1.digest_bytes() + r2.digest_bytes()}")

    # ------------------------------------------------------------------ gates
    for name, ratio in gates.items():
        rows.append(f"clock/gate/{name},0,ratio={ratio:.1f}x")
        if ratio < GATE:
            raise RuntimeError(
                f"interval clock {name} advantage {ratio:.1f}x < {GATE}x "
                f"gate at n={n} churn=50%")
    if folds != 0 or not (r1.skipped and r2.skipped):
        raise RuntimeError(
            f"churned converged pair folded element ranges "
            f"(folds={folds}, skipped={r1.skipped and r2.skipped})")
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
