"""Benchmark runner: one section per paper table/figure + framework planes.

Prints ``name,us_per_call,derived`` CSV rows.  ``--quick`` shrinks sizes
(used by the test suite); full mode is the reported configuration.
``--metrics-out PATH`` additionally writes a JSON snapshot of the obs
metrics registry (section wall times, kernel-dispatch ledger) plus every
CSV row — the machine-readable sibling of the printed table, uploaded as
a CI artifact by the quick-bench job.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: writes,reads,queries,joins,serve,"
                         "antientropy,recovery,placement,clock,mixed,ckpt,"
                         "kernels,roofline,lint")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a JSON metrics snapshot + rows to PATH")
    args = ap.parse_args(argv)

    from . import (bench_antientropy, bench_checkpoint, bench_clock,
                   bench_joins, bench_kernels, bench_lint, bench_mixed,
                   bench_placement, bench_queries, bench_reads,
                   bench_recovery, bench_serve, bench_writes, roofline)

    sections = {
        "writes": lambda: bench_writes.main(quick=args.quick),     # Tab1/Fig1-3
        "reads": lambda: bench_reads.main(quick=args.quick),       # Tab2/Fig4-5
        "queries": lambda: bench_queries.main(quick=args.quick),   # §4.4
        "joins": lambda: bench_joins.main(quick=args.quick),       # planner
        "serve": lambda: bench_serve.main(quick=args.quick),       # serve layer
        "antientropy":
            lambda: bench_antientropy.main(quick=args.quick),      # §6 / AE
        "recovery":
            lambda: bench_recovery.main(quick=args.quick),         # WAL replay
        "placement":
            lambda: bench_placement.main(quick=args.quick),        # ring gate
        "clock": lambda: bench_clock.main(quick=args.quick),       # interval gate
        "mixed": lambda: bench_mixed.main(quick=args.quick),       # Fig6
        "ckpt": lambda: bench_checkpoint.main(quick=args.quick),   # framework
        "kernels": lambda: bench_kernels.main(quick=args.quick),
        "roofline": roofline.main,                                  # from dry-run
        "lint": lambda: bench_lint.main(quick=args.quick),          # CI gate cost
    }
    only = set(args.only.split(",")) if args.only else set(sections)

    from repro.obs.metrics import MetricsRegistry, lift_dispatch_stats

    registry = MetricsRegistry()
    collected = []
    print("name,us_per_call,derived")
    for name, fn in sections.items():
        if name not in only:
            continue
        t0 = time.perf_counter()
        try:
            for row in fn():
                print(row)
                collected.append(row)
        except Exception as e:  # keep the harness running
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", file=sys.stderr)
            raise
        elapsed = time.perf_counter() - t0
        registry.gauge(f"bench.section_seconds.{name}").set(elapsed)
        print(f"# section {name} took {elapsed:.1f}s", file=sys.stderr)

    if args.metrics_out:
        lift_dispatch_stats(registry)  # process-wide kernel-launch ledger
        with open(args.metrics_out, "w") as fh:
            json.dump({"metrics": registry.snapshot(), "rows": collected},
                      fh, indent=1)
        print(f"# metrics snapshot -> {args.metrics_out}", file=sys.stderr)


if __name__ == "__main__":
    main()
