"""Kernel-plane benchmarks.

Wall-clock here is the **pure-jnp reference on CPU** (Pallas interpret mode
measures Python, not TPU): the numbers are throughput sanity checks for the
paper-technique ops (dot-seen filtering ~ the read-fold hot loop, clock
joins ~ delta apply).  The TPU-side story for each Pallas kernel is static:
VMEM working set + arithmetic intensity, reported per kernel from its
BlockSpec geometry (see EXPERIMENTS.md §Roofline / kernels table).
"""
from __future__ import annotations

import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import vclock
from repro.kernels.clock_ops import ref as clock_ref
from repro.kernels.dot_seen.ref import dot_seen_ref


def _time(fn, *args, iters=20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _rand_runs(rng, n_actors: int, n_runs: int):
    """Random canonical (sorted, disjoint, non-adjacent) interval arrays."""
    gaps = rng.integers(2, 20, (n_actors, n_runs))
    lens = rng.integers(0, 63, (n_actors, n_runs))
    ends = np.cumsum(gaps + lens, axis=1)
    starts = ends - lens
    return (jnp.asarray(starts, jnp.int32), jnp.asarray(ends, jnp.int32),
            int(ends.max()))


def main(quick=False) -> List[str]:
    rows = []
    rng = np.random.default_rng(0)
    n_dots = 1 << (16 if quick else 20)
    A, R = 64, 256
    starts, ends, maxc = _rand_runs(rng, A, R)
    actors = jnp.asarray(rng.integers(0, A, n_dots), jnp.int32)
    counters = jnp.asarray(rng.integers(1, maxc, n_dots), jnp.int32)
    f = jax.jit(dot_seen_ref)
    dt = _time(f, starts, ends, actors, counters)
    rows.append(f"kernel/dot_seen_ref/{n_dots},{dt * 1e6:.1f},"
                f"{n_dots / dt / 1e6:.1f}Mdots/s")

    # runs are causal metadata: 128 runs/actor is already a heavily churned
    # clock.  The boundary sweep is O(P^2) per actor row (P = Ra + Rb
    # candidate edges), so throughput is reported in run-merges/s.
    AJ, RJ = 512, 128
    a_s, a_e, _ = _rand_runs(rng, AJ, RJ)
    b_s, b_e, _ = _rand_runs(rng, AJ, RJ)
    fj = jax.jit(clock_ref.join_ref)
    dt = _time(fj, a_s, a_e, b_s, b_e)
    rows.append(f"kernel/clock_join/{AJ}x{RJ}runs,{dt * 1e6:.1f},"
                f"{AJ * RJ * 2 / dt / 1e6:.1f}Mruns/s")

    fp = jax.jit(clock_ref.popcount_ref)
    dt = _time(fp, a_s, a_e)
    rows.append(f"kernel/clock_popcount/{AJ}x{RJ}runs,{dt * 1e6:.1f},"
                f"{a_s.size * 4 * 2 / 1e9 / dt:.1f}GB/s")

    # static TPU-side kernel geometry (BlockSpec working sets)
    rows.append("kernel/flash_attention/vmem,0,"
                "BQ=BKV=128xD<=256: qkv 384KiB + acc 128KiB < 1MiB VMEM; "
                "AI=O(BKV) flops/byte -> compute-bound on MXU")
    rows.append("kernel/decode_attention/vmem,0,"
                "group-padded rows x BKV=256: streams cache once; "
                "AI~2 flops/byte -> HBM-bound (roofline: memory term)")
    rows.append("kernel/mamba_scan/vmem,0,"
                "state 512x16 f32 = 32KiB resident; one pass over x/dt/B/C")
    rows.append("kernel/dot_seen/vmem,0,"
                "clock (starts+ends interval arrays) resident ~256KiB "
                "@ A=128,R=256; one-hot MXU row gather + broadcast interval "
                "test, dots streamed in 1024-blocks")
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
