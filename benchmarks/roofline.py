"""Roofline table generator: reads dry-run artifacts, emits the §Roofline
markdown table + per-cell one-liners (what would move the dominant term)."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

ART_DIR = Path(__file__).resolve().parent / "artifacts" / "dryrun"

ADVICE = {
    "compute": "raise useful-flops ratio (less remat recompute) or grow "
               "per-chip batch until memory-bound",
    "memory": "cut HBM traffic: fuse/flash the attention reads, microbatch, "
              "shard the largest live buffer (see mem column)",
    "collective": "reduce resharding: fewer layout switches between sharded "
                  "ops, overlap collectives with compute, or move the axis "
                  "the traffic rides on",
}


def load(mesh: str = "single", tag: str = "") -> List[Dict]:
    recs = []
    for p in sorted(ART_DIR.glob(f"*__{mesh}{tag}.json")):
        r = json.loads(p.read_text())
        if tag == "" and len(p.stem.split("__")) != 3:
            continue  # skip tagged variants in the baseline table
        recs.append(r)
    return recs


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def table(mesh: str = "single") -> str:
    rows = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_GFLOP/chip | useful | roofline frac | mem GiB/chip | fits |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        if "skipped" in r:
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped |  |  |  |  | "
                f"{r['skipped'][:40]} |")
            continue
        rl = r["roofline"]
        mem_gib = r["memory"]["peak_estimate_bytes"] / 2**30
        fits = "✓" if mem_gib <= 16.0 else f"✗ ({mem_gib:.1f})"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rl['t_compute_s'])} | "
            f"{fmt_s(rl['t_memory_s'])} | {fmt_s(rl['t_collective_s'])} | "
            f"{rl['dominant']} | {rl['model_flops_per_chip'] / 1e9:.1f} | "
            f"{rl['useful_flops_ratio']:.2f} | {rl['roofline_fraction']:.3f} | "
            f"{mem_gib:.2f} | {fits} |")
    return "\n".join(rows)


def advice_lines(mesh: str = "single") -> List[str]:
    out = []
    for r in load(mesh):
        if "skipped" in r:
            continue
        d = r["roofline"]["dominant"]
        out.append(f"- **{r['arch']} × {r['shape']}** ({d}-bound): {ADVICE[d]}")
    return out


def main() -> List[str]:
    rows = []
    for r in load("single"):
        if "skipped" in r:
            continue
        rl = r["roofline"]
        dom_t = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
        rows.append(
            f"roofline/{r['arch']}/{r['shape']},{dom_t * 1e6:.0f},"
            f"dom={rl['dominant']};frac={rl['roofline_fraction']:.3f};"
            f"useful={rl['useful_flops_ratio']:.2f}")
    return rows


if __name__ == "__main__":
    print(table("single"))
