"""Crash-recovery smoke: kill a replica mid-batch, restart, heal.

The durability acceptance path as a gating benchmark: write through a
3-replica durable cluster, tear one replica's WAL at a seeded byte
offset mid-batch, restart it, and measure (a) WAL replay restoring the
acknowledged prefix with zero network traffic, (b) scheduled
anti-entropy shipping exactly the lost tail — dot-bounded, no full
folds.  Raises on any invariant violation so the quick-bench CI job
goes red, and prints replay/heal timings as CSV rows.
"""
from __future__ import annotations

import time
from typing import List

from repro.cluster.clusters import BigsetCluster
from repro.storage import CrashError, CrashPoint

S = b"s"


def run_recovery(n: int, group_depth: int = 8) -> List[str]:
    big = BigsetCluster(3, durable=True, group_depth=group_depth)
    for i in range(n):
        big.add(S, i.to_bytes(4, "big"), coordinator=i % 3)

    media = big.media["vnode0"]
    # seeded kill point: the next fsync tears the log 40 bytes past the
    # current durable end, mid-record
    media.schedule_crash(
        CrashPoint(wal_bytes=len(media.wal) + media.wal_pending() + 40))
    lost = []
    for i in range(n, n + 4 * group_depth):
        try:
            big.add(S, i.to_bytes(4, "big"), coordinator=0)
        except CrashError:
            break
        lost.append(i)
    else:
        raise RuntimeError("scheduled crash point never fired")
    big.crash(0)

    t0 = time.perf_counter()
    rec = big.restart(0)
    replay_s = time.perf_counter() - t0
    if rec.batches_replayed == 0:
        raise RuntimeError("recovery replayed nothing from the WAL")
    if rec.torn_bytes == 0:
        raise RuntimeError("the torn final record went unnoticed")

    survivors = big.vnodes["vnode0"].value(S)
    acked = {i.to_bytes(4, "big") for i in range(n)}
    if not acked <= survivors:
        missing = len(acked - survivors)
        raise RuntimeError(f"{missing} acknowledged writes lost in replay")

    scanned_before = big.ae_stats().keys_scanned
    t0 = time.perf_counter()
    ticks = 0
    want = big.vnodes["vnode1"].value(S)
    while big.vnodes["vnode0"].value(S) != want and ticks < 40:
        big.tick()
        big.settle()
        ticks += 1
    heal_s = time.perf_counter() - t0
    if big.vnodes["vnode0"].value(S) != want:
        raise RuntimeError("anti-entropy failed to heal the lost tail")
    stats = big.ae_stats()
    scanned = stats.keys_scanned - scanned_before
    if stats.keys_shipped != len(lost):
        raise RuntimeError(
            f"heal shipped {stats.keys_shipped} keys for a "
            f"{len(lost)}-key tail")
    # dot-bounded heal: folds touch only the digest buckets holding the
    # diverged dots — bounded by bucket granularity, never by set size
    if scanned > 2 * 2048:
        raise RuntimeError(
            f"heal folded {scanned} keys for a {len(lost)}-key tail")
    # once converged, further rounds skip at O(causal metadata): zero folds
    big.tick()
    big.settle()
    if big.ae_stats().keys_scanned != stats.keys_scanned:
        raise RuntimeError("converged replicas still fold on sync rounds")
    return [
        f"recovery/replay/{n},{replay_s * 1e6:.1f},"
        f"batches={rec.batches_replayed};skipped={rec.batches_skipped};"
        f"segments={rec.segments};torn_bytes={rec.torn_bytes}",
        f"recovery/heal/{n},{heal_s * 1e6:.1f},"
        f"ticks={ticks};keys_shipped={stats.keys_shipped};"
        f"keys_scanned={scanned};tail={len(lost)}",
    ]


def main(cards=(2000, 5000), quick=False) -> List[str]:
    if quick:
        cards = (500,)
    rows: List[str] = []
    for n in cards:
        rows.extend(run_recovery(n))
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
