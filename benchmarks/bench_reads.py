"""Paper Table 2 / Figures 4-5: read performance vs cardinality.

Pareto-distributed reads over many keys (paper: 1000 keys); bigset reads
stream a fold + quorum merge, Riak reads deserialize the blob.  Also
benchmarks the §4.4 queries (is_member / range) that the paper argues
mitigate the full-read penalty — a blob store must deserialize everything
for the same answer.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.cluster.clusters import BigsetCluster, RiakSetCluster
from repro.query import Count, Membership, QueryExecutor, Range


def build(cluster, n_keys: int, card: int):
    for k in range(n_keys):
        S = b"set%03d" % k
        for i in range(card):
            cluster.add(S, i.to_bytes(4, "big"), coordinator=i % 3)
    return cluster


def run_reads(cluster, n_keys: int, n_reads: int, r: int = 1,
              seed: int = 0) -> Dict[str, float]:
    rng = np.random.default_rng(seed)
    # pareto-ish key popularity (paper cites Petersen's pareto estimation)
    ranks = (rng.pareto(1.5, size=n_reads) * 2).astype(int) % n_keys
    lat = []
    t0 = time.perf_counter()
    for k in ranks:
        t1 = time.perf_counter()
        _ = cluster.value(b"set%03d" % int(k), r=r)
        lat.append(time.perf_counter() - t1)
    wall = time.perf_counter() - t0
    lat_us = np.array(lat) * 1e6
    return {
        "throughput_ops_s": n_reads / wall,
        "mean_us": float(lat_us.mean()),
        "p99_us": float(np.percentile(lat_us, 99)),
    }


def run_queries(cluster: BigsetCluster, n_keys: int, n_ops: int) -> Dict[str, float]:
    rng = np.random.default_rng(1)
    t0 = time.perf_counter()
    for i in range(n_ops):
        S = b"set%03d" % int(rng.integers(n_keys))
        vn = cluster.vnodes[cluster.actors[i % 3]]
        vn.is_member(S, int(rng.integers(4096)).to_bytes(4, "big"))
    member_tp = n_ops / (time.perf_counter() - t0)
    t0 = time.perf_counter()
    for i in range(n_ops):
        S = b"set%03d" % int(rng.integers(n_keys))
        vn = cluster.vnodes[cluster.actors[i % 3]]
        vn.range_query(S, int(rng.integers(2048)).to_bytes(4, "big"), 10)
    range_tp = n_ops / (time.perf_counter() - t0)
    return {"member_tp": member_tp, "range_tp": range_tp}


def run_query_io(cluster: BigsetCluster, card: int) -> Dict[str, int]:
    """Bytes read per query shape — the O(result) vs O(n) comparison.

    Uses the bounded-scan metering (per-query IoStats) that the query
    executor threads through every plan: a full fold pays for every
    element-key, a range/membership query pays for its result plus the
    causal metadata (set-clock + tombstone).
    """
    vn = cluster.vnodes[cluster.actors[0]]
    ex = QueryExecutor(vn)
    S = b"set000"
    lo = (card // 2).to_bytes(4, "big")
    hi = (card // 2 + 10).to_bytes(4, "big")

    meter = vn.store.meter()
    _ = list(vn.fold(S))  # full-set fold: O(n) bytes
    fold_bytes = meter.delta().bytes_read

    member = ex.execute(Membership(S, lo))
    range10 = ex.execute(Range(S, start=lo, end=hi))
    count = ex.execute(Count(S, start=lo, end=hi))
    return {
        "fold": fold_bytes,
        "member": member.stats.bytes_read,
        "range10": range10.stats.bytes_read,
        "count10": count.stats.bytes_read,
    }


def main(cards=(100, 500, 1500), n_keys=10, n_reads=120, quick=False) -> List[str]:
    if quick:
        cards, n_keys, n_reads = (50, 200), 6, 40
    rows = []
    for card in cards:
        riak = build(RiakSetCluster(3), n_keys, card)
        big = build(BigsetCluster(3), n_keys, card)
        big.compact_all()
        rr = run_reads(riak, n_keys, n_reads)
        rb = run_reads(big, n_keys, n_reads)
        rows.append(f"reads/riak/{card},{1e6 / rr['throughput_ops_s']:.1f},"
                    f"tp={rr['throughput_ops_s']:.0f};mean={rr['mean_us']:.0f}us;"
                    f"p99={rr['p99_us']:.0f}us")
        rows.append(f"reads/bigset/{card},{1e6 / rb['throughput_ops_s']:.1f},"
                    f"tp={rb['throughput_ops_s']:.0f};mean={rb['mean_us']:.0f}us;"
                    f"p99={rb['p99_us']:.0f}us")
        q = run_queries(big, n_keys, n_reads)
        rows.append(f"queries/bigset/{card},{1e6 / q['member_tp']:.1f},"
                    f"member_tp={q['member_tp']:.0f};range_tp={q['range_tp']:.0f}")
        io = run_query_io(big, card)
        rows.append(
            f"reads/io/bigset/{card},0,"
            f"fold_bytes={io['fold']};member_bytes={io['member']};"
            f"range10_bytes={io['range10']};count10_bytes={io['count10']}")
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
