"""Embed the generated roofline tables + hillclimb comparisons into
EXPERIMENTS.md (between the marker comments)."""
from __future__ import annotations

import json
import re
from pathlib import Path

from . import roofline

ROOT = Path(__file__).resolve().parents[1]
ART = ROOT / "benchmarks" / "artifacts" / "dryrun"


def variant_rows() -> str:
    """Baseline-vs-variant table for every tagged artifact."""
    out = [
        "| cell | variant | compute | memory | collective | dominant | "
        "mem GiB (base→var) | frac (base→var) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for p in sorted(ART.glob("*__single__*.json")):
        parts = p.stem.split("__")
        arch, shape, _, variant = parts[0], parts[1], parts[2], parts[3]
        var = json.loads(p.read_text())
        base_p = ART / f"{arch}__{shape}__single.json"
        if not base_p.exists() or "skipped" in var:
            continue
        base = json.loads(base_p.read_text())
        b, v = base["roofline"], var["roofline"]
        bm = base["memory"]["peak_estimate_bytes"] / 2**30
        vm = var["memory"]["peak_estimate_bytes"] / 2**30

        def delta(key):
            base_v, var_v = b[key], v[key]
            if base_v <= 0:
                return "—"
            return f"{roofline.fmt_s(var_v)} ({(var_v - base_v) / base_v * 100:+.0f}%)"

        out.append(
            f"| {arch} × {shape} | {variant} | {delta('t_compute_s')} | "
            f"{delta('t_memory_s')} | {delta('t_collective_s')} | "
            f"{b['dominant']}→{v['dominant']} | {bm:.1f}→{vm:.1f} | "
            f"{b['roofline_fraction']:.3f}→{v['roofline_fraction']:.3f} |")
    return "\n".join(out)


def main() -> None:
    exp = (ROOT / "EXPERIMENTS.md").read_text()
    table = roofline.table("single")
    multi = roofline.table("multi")
    block = (f"### Single-pod (16×16 = 256 chips)\n\n{table}\n\n"
             f"### Multi-pod (2×16×16 = 512 chips)\n\n{multi}\n")
    exp = re.sub(
        r"<!-- ROOFLINE_TABLE_SINGLE -->.*?(?=\n\(regenerate)",
        f"<!-- ROOFLINE_TABLE_SINGLE -->\n{block}",
        exp, flags=re.S)
    exp = re.sub(
        r"<!-- PERF_CELLS -->.*?(?=\n## §Kernels|\Z)",
        f"<!-- PERF_CELLS -->\n\n{variant_rows()}\n",
        exp, flags=re.S)
    (ROOT / "EXPERIMENTS.md").write_text(exp)
    print("embedded tables into EXPERIMENTS.md")


if __name__ == "__main__":
    main()
