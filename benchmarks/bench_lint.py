"""bigset-lint wall-time benchmark: analyzer cost stays visible.

The lint job gates CI, so its runtime is a tax on every push — this row
keeps that tax on the same dashboard as the paper tables.  Two rows:

* ``full_pack_src`` — the shipped config over the whole ``src`` tree
  (exactly what the CI lint job runs); derived column reports files,
  rules, findings (must be 0), and suppressions.
* ``per_file`` — the same run amortized per file, the number that should
  stay flat as the tree and the rule pack both grow.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import List

from repro.analysis import run_lint

SRC = Path(__file__).resolve().parent.parent / "src"


def main(quick: bool = False) -> List[str]:
    reps = 1 if quick else 3
    best = None
    result = None
    for _ in range(reps):
        t0 = time.perf_counter()
        result = run_lint([str(SRC)])
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    if result.findings:  # the gate itself: a dirty tree fails the bench too
        raise RuntimeError(
            "bigset-lint found violations in src:\n"
            + "\n".join(f.render() for f in result.findings))
    us = best * 1e6
    rows = [
        f"lint/full_pack_src,{us:.0f},files={result.files_checked};"
        f"rules={len(result.rules)};findings=0;"
        f"suppressed={result.suppressed}",
        f"lint/per_file,{us / max(1, result.files_checked):.1f},"
        f"amortized over {result.files_checked} files",
    ]
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
