"""Partitioned-placement economics: storage split, rebalance cost, coverage.

The ring's three claims as a gating benchmark (docs/ARCHITECTURE.md
§ placement & handoff, invariant 13):

* **Storage partitions.**  At 8 vnodes / factor 3 each vnode stores
  ~3/8 of what a full-replication member stores — gated against the
  *measured* full-replication baseline, not a constant.
* **Rebalance ships only moved partitions.**  `add_vnode` handoff
  ships exactly the moved partitions' keys (O(moved data), zero folds
  for unmoved partitions) and its wire bytes stay a minority share of
  the full data set's replication traffic.
* **Coverage reads touch only planned vnodes.**  A range query over a
  coverage plan leaves every vnode outside the plan with zero read IO.

Raises on any violation so the quick-bench CI job goes red; prints
timing rows (the handoff-rounds row rides into ``--metrics-out``).
"""
from __future__ import annotations

import time
from typing import List

from repro.cluster.clusters import BigsetCluster, Ring
from repro.cluster.placement import plan_coverage
from repro.query.plan import Range
from repro.query.planner import side_stats

S = b"bench"
N_VNODES = 8
FACTOR = 3


def _fill(cluster: BigsetCluster, n: int) -> None:
    for i in range(n):
        cluster.add(S, b"el%06d" % i, value=b"v" * 16,
                    coordinator=i % len(cluster.actors))


def _per_vnode_bytes(cluster: BigsetCluster) -> List[int]:
    out = []
    for a in cluster.actors:
        store = cluster.vnodes[a].store
        out.append(sum(side_stats(store, pset).bytes
                       for pset in cluster.ring.storage_sets(S)))
    return out


def run_placement(n: int) -> List[str]:
    actors = [f"v{i}" for i in range(N_VNODES)]

    # -------- storage split vs the measured full-replication baseline
    full = BigsetCluster(ring=Ring.full(actors))
    t0 = time.perf_counter()
    _fill(full, n)
    full_s = time.perf_counter() - t0
    full_bytes = max(_per_vnode_bytes(full))

    part = BigsetCluster(ring=Ring.build(actors, factor=FACTOR))
    t0 = time.perf_counter()
    _fill(part, n)
    part_s = time.perf_counter() - t0
    worst = max(_per_vnode_bytes(part))
    ratio = worst / full_bytes
    # ~3/8 of the full-replication footprint; 1.5x slack absorbs
    # per-partition metadata (clock + tombstone + digest per pset) and
    # rendezvous skew across 64 partitions
    bound = FACTOR / N_VNODES * 1.5
    if ratio > bound:
        raise RuntimeError(
            f"per-vnode storage {ratio:.2f}x of full replication "
            f"(bound {bound:.2f}: factor {FACTOR} over {N_VNODES} vnodes)")

    # -------- coverage reads leave unplanned vnodes cold
    part.settle()  # drain in-flight replication before snapshotting IO
    read_before = {a: part.vnodes[a].store.stats.bytes_read
                   for a in part.actors}
    res = part.query(Range(S, b"el", b"em", limit=200))
    plan = plan_coverage(part.ring, S, live=list(part.actors),
                         r=part.ring.write_quorum())
    covered = set(plan.vnodes)
    if f"vnodes={len(covered)}" not in res.stats.coverage:
        raise RuntimeError(
            f"query coverage {res.stats.coverage!r} disagrees with "
            f"plan_coverage over {len(covered)} vnodes")
    for a in part.actors:
        delta = part.vnodes[a].store.stats.bytes_read - read_before[a]
        if a not in covered and delta:
            raise RuntimeError(
                f"vnode {a} outside the coverage plan read {delta} bytes")

    # -------- rebalance: handoff ships exactly the moved partitions
    base_wire = part.net.bytes_sent  # replication traffic for n elements
    moved_keys = 0
    ae0 = part.ae_stats()
    shipped0, rounds0 = ae0.keys_shipped, ae0.handoff_rounds
    wire0 = part.net.bytes_sent
    delta = part.add_vnode("v8")
    for move in delta.moves:
        pset = part.ring.storage_set(S, move.pid)
        donor = (move.survivors() or move.old_owners)[0]
        moved_keys += side_stats(part.vnodes[donor].store, pset).keys
    t0 = time.perf_counter()
    ticks = 0
    while ticks < 200:
        part.tick(budget=0)  # handoff engine only: no scheduled AE rounds
        state = part.ring_state()
        ticks += 1
        if not state["handoffs_pending"] and not state["retires_pending"]:
            break
    else:
        raise RuntimeError("handoff did not drain in 200 ticks")
    handoff_s = time.perf_counter() - t0
    ae = part.ae_stats()
    shipped = ae.keys_shipped - shipped0
    rounds = ae.handoff_rounds - rounds0
    handoff_wire = part.net.bytes_sent - wire0
    if shipped != moved_keys:
        raise RuntimeError(
            f"handoff shipped {shipped} keys for {moved_keys} moved")
    # O(moved partitions): a ~22/64 rebalance must cost well under the
    # traffic that replicated the full data set in the first place
    if handoff_wire > base_wire // 2:
        raise RuntimeError(
            f"rebalance wire {handoff_wire}B vs {base_wire}B to load "
            f"the set — not O(moved partitions)")
    if part.ring_state()["serveable_epochs"] != [1]:
        raise RuntimeError("old epoch failed to retire after handoff")

    return [
        f"placement/storage/{n},{part_s * 1e6 / n:.2f},"
        f"worst_vnode_ratio={ratio:.3f};bound={bound:.3f};"
        f"full_us_per_add={full_s * 1e6 / n:.2f}",
        f"placement/coverage/{n},0,"
        f"plan_vnodes={len(covered)};of={N_VNODES}",
        f"placement/handoff/{n},{handoff_s * 1e6:.1f},"
        f"handoff_rounds={rounds};keys_shipped={shipped};"
        f"moved_pids={len(delta.moves)};ticks={ticks};"
        f"wire_bytes={handoff_wire}",
    ]


def main(cards=(5000,), quick=False) -> List[str]:
    if quick:
        cards = (800,)
    rows: List[str] = []
    for n in cards:
        rows.extend(run_placement(n))
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
