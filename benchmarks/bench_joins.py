"""Join strategy benchmark: zipper vs gallop vs planner across skew.

The planner's bet (``repro/query/planner.py``) is that a skewed intersect
should cost the *smaller* side, not the sum of both.  This benchmark makes
that a number: intersect latency and per-query IoStats (bytes read,
keys scanned) for the forced zipper, the forced gallop, and the planner's
own choice, at 1:1, 1:100, and 1:10000 cardinality ratios.  At 1:1 the
planner must stay with the zipper (galloping a balanced join pays a seek
per element for nothing); past the crossover it must flip to gallop and
hold keys_scanned flat while the zipper row grows with the big side.
"""
from __future__ import annotations

import time
from typing import List

from repro.core.bigset import BigsetVnode
from repro.query import Join, QueryExecutor
from repro.storage.lsm import LsmStore

SMALL = b"jsmall"
BIG = b"jbig"


def build(small_card: int, ratio: int) -> BigsetVnode:
    """SMALL ⊂ BIG with |BIG| = ratio × |SMALL| (intersection = SMALL)."""
    vn = BigsetVnode("a", LsmStore(memtable_limit=1 << 20))
    big_card = small_card * ratio
    for i in range(big_card):
        vn.coordinate_insert(BIG, b"%08d" % i)
    step = max(1, big_card // small_card)
    for i in range(0, big_card, step):
        vn.coordinate_insert(SMALL, b"%08d" % i)
    vn.store.flush()  # one sorted run: stats and seeks are bisects
    return vn


def _time(fn, n_ops: int) -> float:
    t0 = time.perf_counter()
    for _ in range(n_ops):
        fn()
    return (time.perf_counter() - t0) / n_ops * 1e6  # us/op


def main(quick: bool = False) -> List[str]:
    small_card = 4 if quick else 16
    ratios = (1, 100, 10_000)
    n_ops = 3 if quick else 8
    rows = []
    for ratio in ratios:
        vn = build(small_card, ratio)
        ex = QueryExecutor(vn)
        for name, strategy in (("zipper", "zipper"), ("gallop", "gallop"),
                               ("planner", None)):
            plan = Join("intersect", SMALL, BIG, strategy=strategy)
            res = ex.execute(plan)
            us = _time(lambda p=plan: ex.execute(p), n_ops)
            rows.append(
                f"joins/{name}/intersect/1:{ratio},{us:.1f},"
                f"strategy={res.stats.strategy}")
            rows.append(
                f"joins/{name}/intersect_bytes/1:{ratio},"
                f"{res.stats.bytes_read},"
                f"keys_scanned={res.stats.keys_scanned}")
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
