"""Anti-entropy cost benchmark: digest-first sync vs the full-fold baseline.

The paper's headline claim is that bigset op cost tracks causal metadata,
not cardinality; this section holds anti-entropy to the same bar:

* ``converged_digest`` — a converged pair's sync round must cost digest
  bytes only: **zero element-range folds** (``element_folds`` counts
  ``num_seeks`` across both stores during the rounds), however big the set.
* ``converged_fullsync`` — the pre-digest baseline on the same pair: two
  full folds per direction regardless of convergence.
* ``diverged`` — after ``k`` divergent writes into a
  ``n``-element set, the digest sync ships exactly ``k`` keys and its
  ``keys_scanned`` is bounded by the diverged fenced subranges, not ``n``.
* ``scheduler`` — end to end: read-repair hits feed the scheduler, ticks
  pump rounds through the network, the straggler converges; the derived
  column is the AntiEntropyStats ledger.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.cluster.antientropy import full_sync, sync_pull
from repro.cluster.clusters import BigsetCluster
from repro.core.bigset import BigsetVnode
from repro.query.plan import Range
from repro.storage.lsm import LsmStore

S = b"aeset"


def build_pair(n: int) -> Tuple[BigsetVnode, BigsetVnode]:
    # fence the digest into ~64 subranges whatever the scale, so the quick
    # and full configurations exercise the same divergence-location path
    limit = max(64, n // 64)
    a = BigsetVnode("a", LsmStore(memtable_limit=1 << 20),
                    digest_bucket_limit=limit)
    b = BigsetVnode("b", LsmStore(memtable_limit=1 << 20),
                    digest_bucket_limit=limit)
    for i in range(n):
        b.replica_insert(a.coordinate_insert(S, b"%08d" % i))
    a.store.flush()
    b.store.flush()
    return a, b


def main(quick: bool = False) -> List[str]:
    n = 2_000 if quick else 100_000
    k = 10 if quick else 100
    reps = 5 if quick else 20
    rows = []
    a, b = build_pair(n)

    # -------------------------------------------- converged: digest ladder
    # warm-up pull: absorbs the one-off batched apply of the write phase's
    # buffered digest updates, so the row reports the steady-state round
    sync_pull(a, b, S)
    sync_pull(b, a, S)
    folds0 = a.store.stats.num_seeks + b.store.stats.num_seeks
    t0 = time.perf_counter()
    for _ in range(reps):
        r1 = sync_pull(a, b, S)
        r2 = sync_pull(b, a, S)
    us = (time.perf_counter() - t0) / reps * 1e6
    folds = a.store.stats.num_seeks + b.store.stats.num_seeks - folds0
    rows.append(
        f"antientropy/converged_digest/n{n},{us:.1f},"
        f"element_folds={folds};keys_scanned={r1.keys_scanned + r2.keys_scanned};"
        f"digest_bytes={r1.digest_bytes() + r2.digest_bytes()};"
        f"skipped={r1.skipped and r2.skipped}")

    # ------------------------------------- converged: full-fold baseline
    ma, mb = a.store.meter(), b.store.meter()
    t0 = time.perf_counter()
    full_sync(a, b, S)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(
        f"antientropy/converged_fullsync/n{n},{us:.1f},"
        f"bytes_read={ma.delta().bytes_read + mb.delta().bytes_read}")

    # ------------------------------------------- diverged by k recent writes
    for i in range(k):
        a.coordinate_insert(S, b"~div%06d" % i)
    t0 = time.perf_counter()
    rep = sync_pull(b, a, S)  # b pulls the k new keys from a
    sync_pull(a, b, S)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(
        f"antientropy/diverged_k{k}/n{n},{us:.1f},"
        f"keys_shipped={len(rep.missing)};keys_scanned={rep.keys_scanned};"
        f"payload_bytes={rep.payload_bytes()}")

    # -------------------------------------- scheduler: repair-fed ticks
    big = BigsetCluster(3, sync=False)
    m = 200 if quick else 2_000
    for i in range(m):
        big.add(S, b"%06d" % i)
    big.net.queue.clear()                    # replicas 1, 2 saw nothing
    big.query(Range(S, None, None), r=2)     # read repair heals the quorum
    big.settle()
    t0 = time.perf_counter()
    ticks = 0
    expect = big.vnodes["vnode0"].value(S)
    while big.vnodes["vnode2"].value(S) != expect:
        big.tick()
        big.settle()
        ticks += 1
        if ticks > 50:  # lossless network: convergence takes ~3 ticks
            raise RuntimeError("scheduler failed to converge the straggler")
    us = (time.perf_counter() - t0) * 1e6
    s = big.ae_stats()
    rows.append(
        f"antientropy/scheduler_converge/n{m},{us:.1f},"
        f"ticks={ticks};rounds={s.rounds};skipped={s.rounds_skipped};"
        f"keys_shipped={s.keys_shipped};repair_hits={s.repair_hits};"
        f"digest_bytes={s.digest_bytes};payload_bytes={s.payload_bytes}")
    return rows


if __name__ == "__main__":
    for row in main():
        print(row)
