#!/usr/bin/env python
"""Doctest-style runner: execute every ```python fence in a docs page.

The cookbook's blocks run top to bottom in ONE shared namespace — later
blocks may use names earlier blocks defined, exactly as a reader pasting
them into a REPL would experience.  Any failing assert or exception fails
the run (CI docs job and ``tests/test_docs.py`` both call this), so the
documentation cannot rot away from the code it documents.

Usage:
  python docs/run_cookbook.py [page.md ...]     # default: QUERY_COOKBOOK.md
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
FENCE = re.compile(r"```python\n(.*?)```", re.S)

if str(REPO / "src") not in sys.path:
    sys.path.insert(0, str(REPO / "src"))


def run_file(path) -> int:
    """Execute a page's python blocks; returns how many ran."""
    text = Path(path).read_text()
    blocks = FENCE.findall(text)
    if not blocks:
        raise SystemExit(f"{path}: no ```python blocks found")
    namespace: dict = {"__name__": "__cookbook__"}
    for i, block in enumerate(blocks, 1):
        # compile with a per-block filename so tracebacks point at the page
        code = compile(block, f"{path}#block{i}", "exec")
        exec(code, namespace)
        print(f"  ok: {Path(path).name} block {i} "
              f"({len(block.strip().splitlines())} lines)")
    return len(blocks)


def main(argv=None) -> int:
    paths = argv if argv else [str(REPO / "docs" / "QUERY_COOKBOOK.md")]
    total = sum(run_file(p) for p in paths)
    print(f"cookbook: {total} blocks executed green")
    return total


if __name__ == "__main__":
    main(sys.argv[1:])
