#!/usr/bin/env python
"""Markdown link-check: every relative link in docs/ + README must resolve.

External links (http/https/mailto) and pure fragments are skipped — this
guards the cheap, high-value failure mode: a doc pointing at a file that
was renamed or never existed.  Exits non-zero listing every broken link.

Usage:
  python docs/check_links.py [file-or-dir ...]   # default: README.md docs/
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP = ("http://", "https://", "mailto:", "#")


def broken_links(path: Path) -> list:
    bad = []
    for target in LINK.findall(path.read_text()):
        if target.startswith(SKIP):
            continue
        rel = target.split("#", 1)[0]
        if rel and not (path.parent / rel).exists():
            bad.append(target)
    return bad


def collect(roots) -> list:
    files = []
    for root in roots:
        p = Path(root)
        files.extend(sorted(p.rglob("*.md")) if p.is_dir() else [p])
    return files


def main(argv=None) -> int:
    roots = argv if argv else [str(REPO / "README.md"), str(REPO / "docs")]
    files = collect(roots)
    failures = {f: broken_links(f) for f in files}
    failures = {f: b for f, b in failures.items() if b}
    for f, bad in failures.items():
        print(f"{f}: broken links {bad}", file=sys.stderr)
    if failures:
        raise SystemExit(1)
    print(f"link-check: {len(files)} markdown files ok")
    return len(files)


if __name__ == "__main__":
    main(sys.argv[1:])
